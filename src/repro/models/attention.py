"""GQA attention block: train (chunked flash), prefill (cache fill), decode.

Cross-attention (whisper decoder) reuses the same projections with external
KV and no causal mask.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.flash_attention.ops import chunked_attention, decode_attention, qblock_attention
from .config import ModelConfig
from .layers import apply_rope, dense_init


def init_attention(key, cfg: ModelConfig, dtype):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * dh, dtype=dtype),
        "wk": dense_init(ks[1], d, hkv * dh, dtype=dtype),
        "wv": dense_init(ks[2], d, hkv * dh, dtype=dtype),
        "wo": dense_init(ks[3], hq * dh, d, scale=(hq * dh) ** -0.5 / (2 * cfg.n_layers) ** 0.5, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    return q, k, v


def _causal_attn(q, k, v, cfg: ModelConfig):
    if cfg.attention_impl == "qblock":
        return qblock_attention(
            q, k, v, causal=True, window=cfg.window, chunk=cfg.attn_chunk,
            q_block=cfg.attn_q_block, unroll=not cfg.scan_layers)
    return chunked_attention(q, k, v, causal=True, window=cfg.window,
                             chunk=cfg.attn_chunk, unroll=not cfg.scan_layers)


def attention_train(p, x, cfg: ModelConfig, *, positions=None, rope: bool = True):
    """Full-sequence causal (optionally windowed) attention."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if rope:
        pos = positions if positions is not None else jnp.arange(S)
        q = apply_rope(q, pos, theta=cfg.rope_theta)
        k = apply_rope(k, pos, theta=cfg.rope_theta)
    o = _causal_attn(q, k, v, cfg)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.d_head)
    return o @ p["wo"]


def attention_bidir(p, x, cfg: ModelConfig):
    """Encoder self-attention (whisper encoder): no mask, no rope."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    o = chunked_attention(q, k, v, causal=False, window=0, chunk=cfg.attn_chunk,
                          unroll=not cfg.scan_layers)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.d_head)
    return o @ p["wo"]


def cross_attention(p, x, kv_cache, cfg: ModelConfig):
    """Decoder cross-attn over precomputed encoder K/V ([B,Hkv,T,dh] pair)."""
    B, S, _ = x.shape
    q = (x @ p["wq"] + (p["bq"] if cfg.qkv_bias else 0.0)).reshape(
        B, S, cfg.n_heads, cfg.d_head
    ).transpose(0, 2, 1, 3)
    k, v = kv_cache
    o = chunked_attention(q, k, v, causal=False, window=0, chunk=cfg.attn_chunk,
                          unroll=not cfg.scan_layers)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.d_head)
    return o @ p["wo"]


def encode_cross_kv(p, enc_out, cfg: ModelConfig):
    B, T, _ = enc_out.shape
    k = (enc_out @ p["wk"] + (p["bk"] if cfg.qkv_bias else 0.0)).reshape(
        B, T, cfg.n_kv_heads, cfg.d_head
    ).transpose(0, 2, 1, 3)
    v = (enc_out @ p["wv"] + (p["bv"] if cfg.qkv_bias else 0.0)).reshape(
        B, T, cfg.n_kv_heads, cfg.d_head
    ).transpose(0, 2, 1, 3)
    return k, v


# ------------------------------------------------------------- serving -----


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    shape = (batch, cfg.n_kv_heads, max_len, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_prefill(p, x, cfg: ModelConfig, cache, *, start: int = 0, rope: bool = True):
    """Run causal attention over a prompt chunk and fill the cache in place."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if rope:
        pos = start + jnp.arange(S)
        q = apply_rope(q, pos, theta=cfg.rope_theta)
        k = apply_rope(k, pos, theta=cfg.rope_theta)
    o = _causal_attn(q, k, v, cfg)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.d_head)
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, start, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, start, 0)),
    }
    return o @ p["wo"], cache


def attention_decode(p, x_t, cfg: ModelConfig, cache, kv_len, *, rope: bool = True):
    """One token: append K/V at position ``kv_len`` and attend to the prefix.

    x_t [B, 1, d]; kv_len scalar i32 (tokens already in the cache).

    If the cache buffer is no longer than the attention window, it is treated
    as a *rolling* buffer (writes wrap modulo the buffer, every live entry is
    in-window) — long_500k decode allocates only ``window`` slots.
    """
    B = x_t.shape[0]
    L = cache["k"].shape[2]
    rolling = cfg.window > 0 and L <= cfg.window
    q, k, v = _project_qkv(p, x_t, cfg)
    if rope:
        pos = jnp.full((1,), kv_len, jnp.int32)
        q = apply_rope(q, pos, theta=cfg.rope_theta)
        k = apply_rope(k, pos, theta=cfg.rope_theta)
    slot = jnp.mod(kv_len, L) if rolling else kv_len
    cache_k = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, slot, 0))
    cache_v = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, slot, 0))
    if rolling:
        o = decode_attention(q, cache_k, cache_v, kv_len=jnp.minimum(kv_len + 1, L))
    else:
        o = decode_attention(q, cache_k, cache_v, window=cfg.window, kv_len=kv_len + 1)
    o = o.transpose(0, 2, 1, 3).reshape(B, 1, cfg.n_heads * cfg.d_head)
    return o @ p["wo"], {"k": cache_k, "v": cache_v}
