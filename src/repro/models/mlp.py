"""Feed-forward variants: SwiGLU (llama/deepseek/qwen), GeGLU (gemma),
GELU (whisper), squared-ReLU (nemotron-4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None, dtype=jnp.float32):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    out_scale = ff ** -0.5 / (2 * cfg.n_layers) ** 0.5
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d, ff, dtype=dtype),
            "w_up": dense_init(ks[1], d, ff, dtype=dtype),
            "w_down": dense_init(ks[2], ff, d, scale=out_scale, dtype=dtype),
        }
    return {
        "w_up": dense_init(ks[0], d, ff, dtype=dtype),
        "w_down": dense_init(ks[1], ff, d, scale=out_scale, dtype=dtype),
    }


def mlp_forward(p, x, cfg: ModelConfig):
    act = cfg.mlp_act
    if act == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if act == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    h = x @ p["w_up"]
    if act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu2":  # squared ReLU (Primer / nemotron-4)
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(f"unknown mlp_act {act}")
    return h @ p["w_down"]
