"""Decoder-only LM stack covering dense / MoE / SSM / hybrid / VLM families.

Layers are organized into *segments*: a segment is a block pattern (e.g.
``("rec", "rec", "att")``) stacked ``n_repeats`` times, executed with
``lax.scan`` over the stacked params — HLO size stays depth-independent
(126-layer llama3 compiles as one stacked block), which the 512-device CPU
dry-run depends on.  ``jax.checkpoint`` wraps each scanned group (remat).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .attention import (
    attention_decode,
    attention_prefill,
    attention_train,
    init_attention,
    init_kv_cache,
)
from .config import ModelConfig
from .layers import embed_init, rmsnorm
from .mlp import init_mlp, mlp_forward
from .moe import init_moe, moe_forward
from .rglru import init_rglru, init_rglru_cache, rglru_decode, rglru_forward
from .ssm import init_ssm, init_ssm_cache, ssm_decode, ssm_forward

AUX_KEYS = ("moe_lb_loss", "moe_z_loss", "moe_drop_frac")


def maybe_scan(body, carry, xs, cfg: "ModelConfig", length: int):
    """lax.scan over stacked layer params, or a Python unroll when
    cfg.scan_layers=False (exact per-op cost_analysis for the roofline pass)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        sl = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


class Segment(NamedTuple):
    pattern: tuple  # block kinds, e.g. ("att",) or ("rec","rec","att")
    repeats: int


def plan_segments(cfg: ModelConfig) -> list[Segment]:
    if cfg.family in ("dense", "vlm"):
        return [Segment(("att",), cfg.n_layers)]
    if cfg.family == "moe":
        return [Segment(("moe",), cfg.n_layers)]
    if cfg.family == "ssm":
        return [Segment(("ssm",), cfg.n_layers)]
    if cfg.family == "hybrid":
        pat = tuple(cfg.block_pattern)
        reps, rem = divmod(cfg.n_layers, len(pat))
        segs = [Segment(pat, reps)]
        if rem:
            segs.append(Segment(pat[:rem], 1))
        return segs
    raise ValueError(cfg.family)


# ----------------------------------------------------------------- blocks ---


def init_block(key, cfg: ModelConfig, kind: str, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p = {"ln1": jnp.zeros((d,), dtype)}
    if kind == "att":
        p["attn"] = init_attention(ks[0], cfg, dtype)
        p["ln2"] = jnp.zeros((d,), dtype)
        p["mlp"] = init_mlp(ks[1], cfg, dtype=dtype)
    elif kind == "moe":
        p["attn"] = init_attention(ks[0], cfg, dtype)
        p["ln2"] = jnp.zeros((d,), dtype)
        p["moe"] = init_moe(ks[1], cfg, dtype)
    elif kind == "ssm":
        p["ssm"] = init_ssm(ks[0], cfg, dtype)
    elif kind == "rec":
        p["rec"] = init_rglru(ks[0], cfg, dtype)
        p["ln2"] = jnp.zeros((d,), dtype)
        p["mlp"] = init_mlp(ks[1], cfg, dtype=dtype)
    else:
        raise ValueError(kind)
    return p


def block_train(p, x, cfg: ModelConfig, kind: str):
    aux = {k: jnp.zeros(()) for k in AUX_KEYS}
    if kind == "att":
        x = x + attention_train(p["attn"], rmsnorm(x, p["ln1"], eps=cfg.norm_eps), cfg)
        x = x + mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], eps=cfg.norm_eps), cfg)
    elif kind == "moe":
        x = x + attention_train(p["attn"], rmsnorm(x, p["ln1"], eps=cfg.norm_eps), cfg)
        y, aux = moe_forward(p["moe"], rmsnorm(x, p["ln2"], eps=cfg.norm_eps), cfg)
        x = x + y.astype(x.dtype)
    elif kind == "ssm":
        y, _cache = ssm_forward(p["ssm"], rmsnorm(x, p["ln1"], eps=cfg.norm_eps), cfg)
        x = x + y.astype(x.dtype)
    elif kind == "rec":
        y, _cache = rglru_forward(p["rec"], rmsnorm(x, p["ln1"], eps=cfg.norm_eps), cfg)
        x = x + y.astype(x.dtype)
        x = x + mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], eps=cfg.norm_eps), cfg)
    return x, aux


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype):
    if kind in ("att", "moe"):
        return init_kv_cache(cfg, batch, max_len, dtype)
    if kind == "ssm":
        return init_ssm_cache(cfg, batch, dtype)
    if kind == "rec":
        c = init_rglru_cache(cfg, batch, dtype)
        # local-attention hybrids never need more than the window in cache
        return c
    raise ValueError(kind)


def block_prefill(p, x, cfg: ModelConfig, kind: str, cache, start):
    if kind in ("att", "moe"):
        h, cache = attention_prefill(
            p["attn"], rmsnorm(x, p["ln1"], eps=cfg.norm_eps), cfg, cache, start=start
        )
        x = x + h
        if kind == "att":
            x = x + mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], eps=cfg.norm_eps), cfg)
        else:
            y, _ = moe_forward(p["moe"], rmsnorm(x, p["ln2"], eps=cfg.norm_eps), cfg)
            x = x + y.astype(x.dtype)
    elif kind == "ssm":
        y, cache = ssm_forward(p["ssm"], rmsnorm(x, p["ln1"], eps=cfg.norm_eps), cfg)
        x = x + y.astype(x.dtype)
    elif kind == "rec":
        y, rec_cache = rglru_forward(p["rec"], rmsnorm(x, p["ln1"], eps=cfg.norm_eps), cfg)
        x = x + y.astype(x.dtype)
        cache = rec_cache
        x = x + mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], eps=cfg.norm_eps), cfg)
    return x, cache


def block_decode(p, x_t, cfg: ModelConfig, kind: str, cache, kv_len):
    if kind in ("att", "moe"):
        h, cache = attention_decode(
            p["attn"], rmsnorm(x_t, p["ln1"], eps=cfg.norm_eps), cfg, cache, kv_len
        )
        x_t = x_t + h
        if kind == "att":
            x_t = x_t + mlp_forward(p["mlp"], rmsnorm(x_t, p["ln2"], eps=cfg.norm_eps), cfg)
        else:
            y, _ = moe_forward(p["moe"], rmsnorm(x_t, p["ln2"], eps=cfg.norm_eps), cfg)
            x_t = x_t + y.astype(x_t.dtype)
    elif kind == "ssm":
        y, cache = ssm_decode(p["ssm"], rmsnorm(x_t, p["ln1"], eps=cfg.norm_eps), cfg, cache)
        x_t = x_t + y.astype(x_t.dtype)
    elif kind == "rec":
        y, cache = rglru_decode(p["rec"], rmsnorm(x_t, p["ln1"], eps=cfg.norm_eps), cfg, cache)
        x_t = x_t + y.astype(x_t.dtype)
        x_t = x_t + mlp_forward(p["mlp"], rmsnorm(x_t, p["ln2"], eps=cfg.norm_eps), cfg)
    return x_t, cache


# ------------------------------------------------------------------ model ---


def init_params(rng, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_blocks, k_head = jax.random.split(rng, 3)
    params = {"embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype)}
    segs = plan_segments(cfg)
    for si, seg in enumerate(segs):
        seg_params = {}
        for ki, kind in enumerate(seg.pattern):
            keys = jax.random.split(jax.random.fold_in(k_blocks, si * 16 + ki), seg.repeats)
            seg_params[f"k{ki}"] = jax.vmap(
                lambda k: init_block(k, cfg, kind, dtype)
            )(keys)
        params[f"seg{si}"] = seg_params
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(k_head, cfg.vocab_size, cfg.d_model, dtype)
    return params


def _embed(params, cfg: ModelConfig, tokens, patch_embeds=None):
    x = params["embed"][tokens]
    # the vocab-sharded table gather leaves x batch-replicated under SPMD
    # (16x activation blowup measured on deepseek train_4k); re-pin it to DP
    from ..parallel.sharding import constrain_batch

    x = constrain_batch(x)
    if cfg.family == "vlm" and patch_embeds is not None:
        P = patch_embeds.shape[1]
        x = jax.lax.dynamic_update_slice(
            x, patch_embeds.astype(x.dtype), (0, 0, 0)
        ) if P == x.shape[1] else jnp.concatenate(
            [patch_embeds.astype(x.dtype), x[:, P:]], axis=1
        )
    return x


def _logits(params, cfg: ModelConfig, x):
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,vd->bsv", x, head).astype(jnp.float32)


def forward(params, cfg: ModelConfig, tokens, patch_embeds=None):
    """Teacher-forced full-sequence forward -> (logits f32[B,S,V], aux)."""
    x = _embed(params, cfg, tokens, patch_embeds)
    aux_tot = {k: jnp.zeros(()) for k in AUX_KEYS}
    for si, seg in enumerate(plan_segments(cfg)):
        seg_params = params[f"seg{si}"]

        def group(x, layer_params, _seg=seg):
            from ..parallel.sharding import constrain_batch, gather_fsdp

            if cfg.seq_shard:
                # SP: boundaries (saved by remat) stay seq-sharded over
                # 'model'; re-gather the activation HERE so the TP matmuls
                # see full-seq x — otherwise SPMD replicates the *weights*
                # (measured 14.3 GB/layer of all-gathers on llama3-405b,
                # EXPERIMENTS.md §Perf)
                x = constrain_batch(x)
            if cfg.explicit_fsdp_gather:
                # ZeRO-3 gather made explicit, TP sharding preserved — under
                # remat the gathered weights are temps, re-gathered in bwd
                layer_params = gather_fsdp(layer_params)
            aux_g = {k: jnp.zeros(()) for k in AUX_KEYS}
            for ki, kind in enumerate(_seg.pattern):
                x, aux = block_train(layer_params[f"k{ki}"], x, cfg, kind)
                aux_g = {k: aux_g[k] + aux[k] for k in AUX_KEYS}
            return x, aux_g

        if cfg.remat:
            group = jax.checkpoint(group)

        def scan_body(x, layer_params):
            if cfg.seq_shard:
                from ..parallel.sharding import maybe_shard_seq

                x = maybe_shard_seq(x)
            return group(x, layer_params)

        x, auxs = maybe_scan(scan_body, x, seg_params, cfg, seg.repeats)
        aux_tot = {k: aux_tot[k] + auxs[k].sum() for k in AUX_KEYS}
    x = rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
    return _logits(params, cfg, x), aux_tot


def loss_fn(params, cfg: ModelConfig, batch):
    """Next-token cross-entropy + MoE aux losses.  batch: tokens, loss_mask?,
    patch_embeds? (vlm)."""
    tokens = batch["tokens"]
    logits, aux = forward(params, cfg, tokens, batch.get("patch_embeds"))
    logits = logits[:, :-1]
    targets = tokens[:, 1:]
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(targets, jnp.float32) if mask is None else mask[:, 1:].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss + 0.01 * aux["moe_lb_loss"] + 1e-3 * aux["moe_z_loss"]
    metrics = dict(aux, nll=loss)
    return total, metrics


# ---------------------------------------------------------------- serving ---


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    cache = {"len": jnp.zeros((), jnp.int32)}
    for si, seg in enumerate(plan_segments(cfg)):
        seg_cache = {}
        for ki, kind in enumerate(seg.pattern):
            one = init_block_cache(cfg, kind, batch, max_len, dtype)
            seg_cache[f"k{ki}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (seg.repeats, *a.shape)).copy(), one
            )
        cache[f"seg{si}"] = seg_cache
    return cache


def prefill(params, cfg: ModelConfig, tokens, cache, patch_embeds=None):
    """Consume the prompt, fill caches, return last-position logits."""
    x = _embed(params, cfg, tokens, patch_embeds)
    S = tokens.shape[1]
    for si, seg in enumerate(plan_segments(cfg)):
        seg_params = params[f"seg{si}"]
        seg_cache = cache[f"seg{si}"]

        def scan_body(x, pc, _seg=seg):
            layer_params, layer_cache = pc
            if cfg.explicit_fsdp_gather:
                from ..parallel.sharding import gather_fsdp

                layer_params = gather_fsdp(layer_params)
            new_caches = {}
            for ki, kind in enumerate(_seg.pattern):
                x, c = block_prefill(
                    layer_params[f"k{ki}"], x, cfg, kind, layer_cache[f"k{ki}"], 0
                )
                new_caches[f"k{ki}"] = c
            return x, new_caches

        x, new_seg_cache = maybe_scan(scan_body, x, (seg_params, seg_cache), cfg, seg.repeats)
        cache = dict(cache, **{f"seg{si}": new_seg_cache})
    x = rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
    logits = _logits(params, cfg, x[:, -1:])
    cache = dict(cache, len=jnp.asarray(S, jnp.int32))
    return logits, cache


def decode_step(params, cfg: ModelConfig, token, cache):
    """token i32[B, 1] -> (logits f32[B, 1, V], updated cache)."""
    x = params["embed"][token]
    kv_len = cache["len"]
    for si, seg in enumerate(plan_segments(cfg)):
        seg_params = params[f"seg{si}"]
        seg_cache = cache[f"seg{si}"]

        def scan_body(x, pc, _seg=seg):
            layer_params, layer_cache = pc
            new_caches = {}
            for ki, kind in enumerate(_seg.pattern):
                x, c = block_decode(
                    layer_params[f"k{ki}"], x, cfg, kind, layer_cache[f"k{ki}"], kv_len
                )
                new_caches[f"k{ki}"] = c
            return x, new_caches

        x, new_seg_cache = maybe_scan(scan_body, x, (seg_params, seg_cache), cfg, seg.repeats)
        cache = dict(cache, **{f"seg{si}": new_seg_cache})
    x = rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
    logits = _logits(params, cfg, x)
    cache = dict(cache, len=kv_len + 1)
    return logits, cache
