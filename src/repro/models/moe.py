"""Mixture-of-Experts FFN with capacity routing.

The router is the paper-unified assignment problem (DESIGN.md §3):
token->expert scores with per-expert capacity, solved by
``kernels.assign.moe_route`` (jnp oracle inside pjit — semantics identical to
the Pallas kernel, which is validated against it).

Routing is *grouped* (GShard-style): tokens are split into
``cfg.router_groups`` independent groups so capacity admission never
serializes across data-parallel shards — groups align with the batch
sharding, experts shard over the ``model`` axis (EP).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..kernels.assign.ops import moe_route
from .config import ModelConfig
from .layers import dense_init


def init_moe(key, cfg: ModelConfig, dtype):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    glu = cfg.mlp_act in ("swiglu", "geglu")
    out_scale = ff ** -0.5 / (2 * cfg.n_layers) ** 0.5

    def expert_mats(k, d_in, d_out, scale):
        keys = jax.random.split(k, E)
        return jnp.stack([dense_init(kk, d_in, d_out, scale=scale, dtype=dtype) for kk in keys])

    p = {
        "router": dense_init(ks[0], d, E, scale=0.02, dtype=jnp.float32),
        "w_up": expert_mats(ks[1], d, ff, d ** -0.5),
        "w_down": expert_mats(ks[2], ff, d, out_scale),
    }
    if glu:
        p["w_gate"] = expert_mats(ks[3], d, ff, d ** -0.5)
    return p


def moe_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    return max(
        1, int(math.ceil(tokens_per_group * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    )


def moe_forward(p, x, cfg: ModelConfig):
    """x [B, S, d] -> (y [B, S, d], aux dict with load-balance/z losses)."""
    B, S, d = x.shape
    T = B * S
    G = cfg.router_groups
    if T % G:
        G = 1
    Tg = T // G
    E, k = cfg.n_experts, cfg.top_k
    C = moe_capacity(cfg, Tg)

    def _c(t, spec):  # sharding constraints (no-op without an ambient mesh)
        from ..parallel.sharding import ambient_axis_names
        from jax.sharding import PartitionSpec as P

        axes = ambient_axis_names()
        if "model" not in axes:
            return t
        DP = tuple(a for a in ("pod", "data") if a in axes) or None
        resolved = P(*[DP if s == "dp" else (s if s in axes else None) for s in spec])
        return jax.lax.with_sharding_constraint(t, resolved)

    xf = _c(x.reshape(G, Tg, d), ("dp", None, None))
    logits = jnp.einsum("gtd,de->gte", xf.astype(jnp.float32), p["router"])

    route_bn = Tg if not cfg.scan_layers else 256  # unrolled measurement
    idx, combine, slot, keep = jax.vmap(
        lambda lg: moe_route(lg, k=k, capacity=C, use_kernel=False, block_n=route_bn)
    )(logits)
    # idx/combine/slot/keep: [G, Tg, k]

    # ---- dispatch: scatter tokens into per-expert capacity buffers ----------
    g_ix = jnp.arange(G)[:, None, None]
    contrib = xf[:, :, None, :] * keep[..., None].astype(x.dtype)  # [G,Tg,k,d]
    buf = jnp.zeros((G, E, C, d), x.dtype).at[g_ix, idx, slot].add(
        contrib, mode="drop"
    )
    # Shard the capacity buffer over DP (groups) ONLY: the data-dependent
    # scatter stays local to each shard, and the expert einsum below slices
    # the replicated E dim for free against 'model'-sharded expert weights
    # (EP).  Sharding E here instead makes SPMD replicate the whole buffer
    # (measured 44-74 GB/dev on granite train_4k; EXPERIMENTS.md §Perf).
    buf = _c(buf, ("dp", None, None, None))

    # ---- expert computation (einsum over the expert dim; EP shards E) -------
    if "w_gate" in p:
        gate = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
        up = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
        act = jax.nn.silu(gate) if cfg.mlp_act == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
        h = jnp.square(jax.nn.relu(h)) if cfg.mlp_act == "relu2" else jax.nn.gelu(h)
    # the all-gather over E of y_buf is this formulation's EP collective
    # (equivalent bytes to the classic token all-to-all)
    y_buf = _c(jnp.einsum("gecf,efd->gecd", h, p["w_down"]), ("dp", None, None, None))

    # ---- combine: gather each token's k slots back ---------------------------
    slot_c = jnp.clip(slot, 0, C - 1)
    y_tok = y_buf[g_ix, idx, slot_c]  # [G, Tg, k, d]
    y = (y_tok * (combine * keep)[..., None].astype(x.dtype)).sum(axis=2)

    # ---- aux losses (Switch/GShard load balancing + router z-loss) ----------
    probs = jax.nn.softmax(logits, axis=-1)                       # [G,Tg,E]
    me = probs.mean(axis=1)                                       # [G,E]
    ce = jnp.zeros((G, E)).at[g_ix[..., 0], idx.reshape(G, -1)].add(
        keep.reshape(G, -1).astype(jnp.float32)
    ) / jnp.maximum(keep.sum(axis=(1, 2))[:, None], 1.0)
    lb_loss = (E * (me * ce).sum(-1)).mean()
    z_loss = jnp.square(jax.nn.logsumexp(logits, axis=-1)).mean()
    drop_frac = 1.0 - keep.mean()
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss, "moe_drop_frac": drop_frac}
    return y.reshape(B, S, d), aux
